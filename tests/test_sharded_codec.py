"""Sharded chunk-grid execution: shard_map parity with the single-device path.

The acceptance bar of the sharded subsystem: placing each shape group's
stacked chunk slab across a 1-D device mesh (``shard="auto"`` / an explicit
mesh) must emit archives byte-identical — and reconstructions, refine
deltas, and progressive accounting bit-identical — to the single-device
jax backend, with one *logical* kernel dispatch per phase whose device
fan-out equals the mesh size.  The mesh, like the batch axis, is an
execution detail, never a format change.

Every parity test here runs at any local device count (an explicit mesh
over all devices degenerates gracefully to 1 device); the tests marked
``skipif device_count < 8`` additionally pin the multi-device behaviour
and run in CI's sharded lane under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""
import jax
import numpy as np
import pytest

from _fields import smooth_field
from repro.core import (CUBIC, compress, decompress, metrics, open_archive,
                        refine, retrieve)
from repro.core.pipeline import backends
from repro.core.pipeline.encode import (MAX_BATCH_CHUNKS, group_cap,
                                        resolve_exec_mesh, shape_groups)
from repro.kernels import dispatch
from repro.parallel import codec_mesh

N_DEV = jax.device_count()

multi_device = pytest.mark.skipif(
    N_DEV < 8, reason="needs the forced 8-device host mesh "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")


def _chunky_field(shape=(50, 41), seed=0, rough=0.01):
    rng = np.random.default_rng(seed)
    return smooth_field(shape, seed) + rough * rng.standard_normal(shape)


def _mesh_all():
    return codec_mesh.codec_mesh()


# ----------------------------------------------------- mesh/axis plumbing

def test_codec_mesh_shape():
    mesh = _mesh_all()
    assert tuple(mesh.axis_names) == (codec_mesh.CODEC_AXIS,)
    assert codec_mesh.shard_count(mesh) == N_DEV
    with pytest.raises(ValueError):
        codec_mesh.codec_mesh(N_DEV + 1)
    with pytest.raises(ValueError):
        codec_mesh.codec_mesh(0)


def test_resolve_shard_contract():
    assert codec_mesh.resolve_shard(None) is None
    assert codec_mesh.resolve_shard(False) is None
    mesh = _mesh_all()
    assert codec_mesh.resolve_shard(mesh) is mesh
    auto = codec_mesh.resolve_shard("auto")
    if N_DEV > 1:  # "auto" shards only when there is something to shard
        assert codec_mesh.shard_count(auto) == N_DEV
    else:
        assert auto is None
    with pytest.raises(ValueError, match="shard must be"):
        codec_mesh.resolve_shard("always")


def test_resolve_shard_rejects_2d_mesh():
    from repro.parallel import compat
    mesh2 = compat.make_mesh((1, 1), ("a", "b"), devices=jax.devices()[:1])
    with pytest.raises(ValueError, match="1-D mesh"):
        codec_mesh.resolve_shard(mesh2)


def test_pad_to_shards():
    mesh = _mesh_all()
    for b in (1, 3, N_DEV, 2 * N_DEV + 1):
        total = b + codec_mesh.pad_to_shards(b, mesh)
        assert total % N_DEV == 0 and total - b < N_DEV


def test_group_cap_scales_with_mesh():
    """MAX_BATCH_CHUNKS stays the per-device working-set bound: a mesh of
    n devices schedules n-times-larger stacks."""
    assert group_cap(None) == MAX_BATCH_CHUNKS
    mesh = _mesh_all()
    assert group_cap(mesh) == MAX_BATCH_CHUNKS * N_DEV
    rows = [3] * (MAX_BATCH_CHUNKS * N_DEV + 2)
    groups = shape_groups(rows, max_group=group_cap(mesh))
    assert [len(g) for g in groups] == [MAX_BATCH_CHUNKS * N_DEV, 2]


def test_backend_sharded_slots():
    """jax ships the sharded primitives; the numpy reference, like with
    batching, deliberately stays a per-chunk loop."""
    jx, np_ = backends.get("jax"), backends.get("numpy")
    assert jx.shards_encode and jx.shards_decode
    assert not np_.shards_encode and not np_.shards_decode


def test_exec_mesh_policy():
    mesh = _mesh_all()
    # explicit mesh without a chunk grid / without the stacked scheduler
    with pytest.raises(ValueError, match="chunk grid"):
        resolve_exec_mesh(mesh, True, chunked=False, batch_chunks=None)
    with pytest.raises(ValueError, match="stacked shape-group"):
        resolve_exec_mesh(mesh, True, chunked=True, batch_chunks=False)
    # "auto" degrades quietly in the same situations
    assert resolve_exec_mesh("auto", True, chunked=False,
                             batch_chunks=None) is None
    assert resolve_exec_mesh("auto", True, chunked=True,
                             batch_chunks=False) is None
    # backends without sharded primitives fall back to their own path
    assert resolve_exec_mesh(mesh, False, chunked=True,
                             batch_chunks=None) is None
    assert resolve_exec_mesh(mesh, True, chunked=True,
                             batch_chunks=None) is mesh


def test_shard_errors_through_public_api():
    x = _chunky_field((20, 10))
    mesh = _mesh_all()
    with pytest.raises(ValueError, match="chunk grid"):
        compress(x, 1e-4, backend="jax", shard=mesh)
    with pytest.raises(ValueError, match="stacked shape-group"):
        compress(x, 1e-4, backend="jax", chunk_elems=50, shard=mesh,
                 batch_chunks=False)
    v1 = compress(x, 1e-4)
    with pytest.raises(ValueError, match="chunk grid"):
        retrieve(v1, error_bound=1e-2, backend="jax", shard=mesh)
    # "auto" is a no-op on v1 rather than an error
    out, _ = retrieve(v1, error_bound=1e-2, backend="jax", shard="auto")
    assert metrics.linf(x, out) <= 1e-2


# --------------------------------------------------------- encode parity

@pytest.mark.slow
@pytest.mark.parametrize("shape,chunk", [((50, 41), 500),   # ragged tail
                                         ((3000,), 700),
                                         ((24, 20, 18), 2000)])
def test_sharded_compress_byte_identical(shape, chunk):
    """Sharded, batched, looped, and numpy archives are the same bytes —
    including ragged shape groups that pad up to the mesh size."""
    x = _chunky_field(shape)
    mesh = _mesh_all()
    b_shard = compress(x, 1e-5, CUBIC, backend="jax", chunk_elems=chunk,
                       shard=mesh)
    b_bat = compress(x, 1e-5, CUBIC, backend="jax", chunk_elems=chunk)
    b_np = compress(x, 1e-5, CUBIC, backend="numpy", chunk_elems=chunk)
    assert b_shard == b_bat == b_np


@pytest.mark.slow
def test_sharded_single_chunk_archive():
    """A one-chunk grid has nothing to split: the scheduler falls through
    to the scalar path and the archive still round-trips."""
    x = _chunky_field((16, 10))
    buf = compress(x, 1e-5, backend="jax", chunk_elems=10 ** 6,
                   shard=_mesh_all())
    assert buf == compress(x, 1e-5, backend="numpy", chunk_elems=10 ** 6)
    assert metrics.linf(x, decompress(buf, backend="jax",
                                      shard=_mesh_all())) <= 1e-5


def test_numpy_backend_shard_is_fallback():
    """Backends without sharded primitives fall back to the loop — bytes
    unchanged, no error, even for an explicit mesh (mirrors how missing
    *_batch slots fall back)."""
    x = _chunky_field((30, 20))
    a = compress(x, 1e-4, backend="numpy", chunk_elems=200,
                 shard=_mesh_all())
    b = compress(x, 1e-4, backend="numpy", chunk_elems=200)
    assert a == b


# --------------------------------------------------------- decode parity

@pytest.mark.slow
@pytest.mark.parametrize("mode", [dict(error_bound=1e-3),
                                  dict(max_bytes=3000), dict()])
def test_sharded_retrieve_bit_identical(mode):
    """Every plan mode: sharded == batched == numpy, bit for bit, with
    identical per-chunk progressive accounting."""
    x = _chunky_field((50, 41))
    buf = compress(x, 1e-5, chunk_elems=500)
    a, sa = retrieve(open_archive(buf), backend="jax", shard=_mesh_all(),
                     **mode)
    b, sb = retrieve(open_archive(buf), backend="jax", **mode)
    c, sc = retrieve(open_archive(buf), backend="numpy", **mode)
    assert np.array_equal(a, b) and np.array_equal(a, c)
    assert sa.bytes_read == sb.bytes_read == sc.bytes_read
    assert sa.err_bound == sb.err_bound == sc.err_bound
    for ca, cb in zip(sa.chunk_states, sb.chunk_states):
        assert ca.planes_loaded == cb.planes_loaded
        assert ca.bytes_read == cb.bytes_read
        assert np.array_equal(ca.xhat, cb.xhat)


@pytest.mark.slow
def test_sharded_refine_after_retrieve():
    """Algorithm 2 on the mesh: every rung of a sharded progressive ladder
    matches the single-device ladder bit-for-bit, refine still fetches
    only missing planes, and the state stays mesh-agnostic (sharded and
    unsharded calls interleave freely on one state)."""
    x = _chunky_field((80, 44), 2)
    buf = compress(x, 1e-6, CUBIC, chunk_elems=900)
    mesh = _mesh_all()
    r1, st1 = open_archive(buf), None
    r2, st2 = open_archive(buf), None
    for i, E in enumerate((1e-1, 1e-3, None)):
        kw = {} if E is None else dict(error_bound=E)
        o1, st1 = retrieve(r1, state=st1, backend="jax", shard=mesh, **kw)
        # interleave: even rungs unsharded, odd rungs sharded
        o2, st2 = retrieve(r2, state=st2, backend="jax",
                           shard=mesh if i % 2 else None, **kw)
        assert np.array_equal(o1, o2)
        assert st1.bytes_read == st2.bytes_read
    # repeating the final bound re-reads nothing and stays exact
    prev = st1.bytes_read
    out, st1 = refine(st1, backend="jax", shard=mesh)
    assert st1.bytes_read == prev
    assert metrics.linf(x, out) <= 1e-6


@pytest.mark.slow
def test_sharded_mixed_plane_prefixes():
    """Byte-budget plans give chunks different plane prefixes, so the
    (nbits, prefix) decode groups are ragged w.r.t. the mesh — sharded
    results must still match the loop exactly."""
    rng = np.random.default_rng(3)
    x = smooth_field((60, 33), 1)
    x[:20] += 0.5 * rng.standard_normal((20, 33))  # chunk 0 much rougher
    buf = compress(x, 1e-6, chunk_elems=700)
    for budget in (4000, 9000):
        a, sa = retrieve(open_archive(buf), max_bytes=budget, backend="jax",
                         shard=_mesh_all())
        b, sb = retrieve(open_archive(buf), max_bytes=budget, backend="jax")
        assert np.array_equal(a, b)
        assert sa.bytes_read == sb.bytes_read


@pytest.mark.slow
def test_sharded_with_escapes_bit_identical():
    """Escaped outliers land in specific chunks: per-chunk override
    writeback must hit the same points on the mesh."""
    x = smooth_field((40, 40), 1)
    x[13, 17] = 1e15
    x[35, 2] = -1e15
    with np.errstate(invalid="ignore"):
        buf = compress(x, 1e-7, CUBIC, chunk_elems=400, backend="jax",
                       shard=_mesh_all())
        assert buf == compress(x, 1e-7, CUBIC, chunk_elems=400,
                               backend="numpy")
    a, _ = retrieve(open_archive(buf), error_bound=1e-2, backend="jax",
                    shard=_mesh_all())
    b, _ = retrieve(open_archive(buf), error_bound=1e-2, backend="jax")
    assert np.array_equal(a, b)


# ------------------------------------------------------ dispatch accounting

@pytest.mark.slow
def test_sharded_dispatch_counts_per_device():
    """The two accounting invariants: sharding leaves the *logical*
    dispatch schedule of the batched engine untouched, and each sharded
    dispatch fans out to exactly one launch per mesh device.  The (48, 41)
    grid splits into 4 equal chunks — one shape group, no ragged tail,
    and 4 < MAX_BATCH_CHUNKS so the mesh-scaled group cap cannot merge
    groups differently — which is what makes the sharded and batched
    logical schedules provably coincide here (they need not in general;
    see kernels/dispatch.py)."""
    x = _chunky_field((48, 41))
    mesh = _mesh_all()
    buf = compress(x, 1e-5, backend="jax", chunk_elems=500)
    with dispatch.measure() as m_bat:
        compress(x, 1e-5, backend="jax", chunk_elems=500)
    with dispatch.measure() as m_sh, dispatch.measure_devices() as md_sh:
        buf_sh = compress(x, 1e-5, backend="jax", chunk_elems=500,
                          shard=mesh)
    assert buf_sh == buf
    assert m_sh == m_bat                      # same logical schedule
    assert md_sh == {k: v * N_DEV for k, v in m_sh.items()}

    retrieve(open_archive(buf), error_bound=1e-3, backend="jax")  # warm
    with dispatch.measure() as d_bat:
        retrieve(open_archive(buf), error_bound=1e-3, backend="jax")
    with dispatch.measure() as d_sh, dispatch.measure_devices() as dd_sh:
        retrieve(open_archive(buf), error_bound=1e-3, backend="jax",
                 shard=mesh)
    assert d_sh == d_bat
    # the reconstruction sweeps always run on the full stack -> exact
    # mesh fan-out; fused plane decodes group by (nbits,) — prefixes are
    # runtime operands — and singleton groups stay unsharded IN BOTH MODES
    # (that is why the logical counts match), so their fan-out is bounded,
    # not exact
    assert dd_sh["interp_recon"] == d_sh["interp_recon"] * N_DEV
    assert dd_sh["decode_fused"] <= d_sh["decode_fused"] * N_DEV
    if N_DEV > 1:  # at least one multi-chunk decode group got sharded
        assert dd_sh["decode_fused"] > d_sh["decode_fused"]


@pytest.mark.slow
def test_unsharded_device_counts_equal_logical():
    x = _chunky_field((48, 41))
    with dispatch.measure() as m, dispatch.measure_devices() as md:
        compress(x, 1e-5, backend="jax", chunk_elems=500)
    assert md == m


# ------------------------------------------------- forced 8-device lane

@multi_device
def test_eight_device_mesh_is_real():
    """CI's sharded lane forces 8 host devices; the auto mesh must span
    all of them and the device fan-out must show 8x."""
    mesh = codec_mesh.resolve_shard("auto")
    assert codec_mesh.shard_count(mesh) == 8
    x = _chunky_field((48, 41))
    with dispatch.measure() as m, dispatch.measure_devices() as md:
        compress(x, 1e-5, backend="jax", chunk_elems=500, shard="auto")
    assert md == {k: v * 8 for k, v in m.items()}


@multi_device
def test_eight_device_more_chunks_than_devices():
    """12 equal chunks over 8 devices: pad-to-mesh plus a 2-rows-per-device
    split, byte/bit-identical to single-device end to end."""
    x = _chunky_field((96, 41), 5)
    buf_sh = compress(x, 1e-5, backend="jax", chunk_elems=350, shard="auto")
    buf = compress(x, 1e-5, backend="numpy", chunk_elems=350)
    assert buf_sh == buf
    assert len(open_archive(buf).meta.chunks) >= 12
    a, _ = retrieve(open_archive(buf), error_bound=1e-4, backend="jax",
                    shard="auto")
    b, _ = retrieve(open_archive(buf), error_bound=1e-4, backend="numpy")
    assert np.array_equal(a, b)
