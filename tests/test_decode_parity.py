"""numpy vs jax (Pallas) DECODE backend parity.

The acceptance bar of the backend-symmetric decode path: ``retrieve`` /
``refine`` / ``decompress`` with ``backend="jax"`` (interpret mode on CPU)
must produce BIT-IDENTICAL arrays to ``backend="numpy"`` on every field —
including the escape-override path, Algorithm 2's incremental zero-anchor
delta cascade, and chunked (v2) archives — plus primitive-level parity of
``decode_level`` (kernel bit-unpack + closed-form XOR-undo + negabinary
decode) against the sequential host reference.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 container has no hypothesis; vendored fallback
    from _hypothesis_shim import given, settings, strategies as st

from _fields import smooth_field
from repro.core import (CUBIC, LINEAR, compress, decompress, jax_backend,
                        metrics, open_archive, refine, retrieve)
from repro.core import bitplane as bp
from repro.core import interpolation, negabinary as nbmod
from repro.core.pipeline import backends


# ------------------------------------------------------ full-array parity

@pytest.mark.parametrize("shape", [(257,), (33, 41), (17, 13, 11)])
@pytest.mark.parametrize("interp", [LINEAR, CUBIC])
def test_decompress_bit_identical_smooth(shape, interp):
    x = smooth_field(shape)
    eb = 1e-4 * (x.max() - x.min())
    buf = compress(x, eb, interp)
    a = decompress(buf, backend="numpy")
    b = decompress(buf, backend="jax")
    assert np.array_equal(a, b)
    assert metrics.linf(x, b) <= eb


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 3), st.integers(0, 10 ** 6),
       st.sampled_from([LINEAR, CUBIC]), st.floats(1e-5, 1e-1))
def test_retrieve_bit_identical_property(ndim, seed, interp, rel_eb):
    """Rough random data: the fma-sensitive regime of the recon kernel."""
    rng = np.random.default_rng(seed)
    shape = tuple(int(rng.integers(2, [120, 24, 12][ndim - 1]))
                  for _ in range(ndim))
    x = rng.standard_normal(shape) * rng.uniform(0.1, 100)
    eb = rel_eb * (x.max() - x.min())
    buf = compress(x, eb, interp)
    E = 10.0 * eb
    a, sa = retrieve(buf, error_bound=E, backend="numpy")
    b, sb = retrieve(buf, error_bound=E, backend="jax")
    assert np.array_equal(a, b)
    assert sa.err_bound == sb.err_bound
    assert sa.bytes_read == sb.bytes_read


def test_decode_bit_identical_with_escapes():
    """Escaped outliers: the exact-override writeback must land identically
    (initial state AND pinned-zero deltas on later refinements)."""
    x = smooth_field((40, 40), 1)
    x[13, 17] = 1e15
    x[0, 0] = -1e15
    with np.errstate(invalid="ignore"):
        buf = compress(x, 1e-7, CUBIC)
    for E in (1e-2, None):
        kw = {} if E is None else dict(error_bound=E)
        a, _ = retrieve(buf, backend="numpy", **kw)
        b, _ = retrieve(buf, backend="jax", **kw)
        assert np.array_equal(a, b)
    assert metrics.linf(x, decompress(buf, backend="jax")) <= 1e-7


def test_refine_delta_cascade_bit_identical():
    """Algorithm 2 on the kernels: every rung of a progressive ladder is
    bit-identical, including the final full-precision refine()."""
    x = smooth_field((48, 36), 2)
    buf = compress(x, 1e-7, CUBIC)
    states = {}
    for bk in ("numpy", "jax"):
        r = open_archive(buf)
        st_, outs = None, []
        for E in (1e-1, 1e-3, 1e-6):
            out, st_ = retrieve(r, error_bound=E, state=st_, backend=bk)
            outs.append(out.copy())
        out, st_ = refine(st_, backend=bk)       # to full precision
        outs.append(out)
        states[bk] = (outs, st_)
    for a, b in zip(states["numpy"][0], states["jax"][0]):
        assert np.array_equal(a, b)
    assert states["numpy"][1].bytes_read == states["jax"][1].bytes_read


def test_backend_switch_mid_refinement():
    """State is backend-agnostic: numpy-started, jax-refined (and vice
    versa) equals a single-backend ladder bit-for-bit."""
    x = smooth_field((40, 30), 7)
    buf = compress(x, 1e-6)
    r1 = open_archive(buf)
    out1, st1 = retrieve(r1, error_bound=1e-2, backend="numpy")
    out1, st1 = retrieve(r1, error_bound=1e-5, state=st1, backend="jax")
    r2 = open_archive(buf)
    out2, st2 = retrieve(r2, error_bound=1e-2, backend="jax")
    out2, st2 = retrieve(r2, error_bound=1e-5, state=st2, backend="numpy")
    r3 = open_archive(buf)
    out3, st3 = retrieve(r3, error_bound=1e-2, backend="numpy")
    out3, st3 = retrieve(r3, error_bound=1e-5, state=st3, backend="numpy")
    assert np.array_equal(out1, out2)
    assert np.array_equal(out1, out3)


def test_chunked_v2_decode_bit_identical():
    """The acceptance path for v2: per-chunk kernel decode == numpy."""
    x = smooth_field((96, 50), 3)
    buf = compress(x, 1e-6, CUBIC, chunk_elems=1000)
    a, sa = retrieve(buf, error_bound=1e-3, backend="numpy")
    b, sb = retrieve(buf, error_bound=1e-3, backend="jax")
    assert np.array_equal(a, b)
    assert sa.bytes_read == sb.bytes_read
    a2, _ = retrieve(sa.reader, state=sa, backend="numpy")
    b2, _ = retrieve(sb.reader, state=sb, backend="jax")
    assert np.array_equal(a2, b2)
    assert metrics.linf(x, b2) <= 1e-6


def test_chunked_v2_batched_decode_bit_identical():
    """Shape-group batched v2 decode (vmapped kernels) == numpy, including
    a mid-ladder refine; dispatch scheduling must not perturb parity."""
    x = smooth_field((96, 50), 3)
    buf = compress(x, 1e-6, CUBIC, chunk_elems=1000)
    a, sa = retrieve(buf, error_bound=1e-3, backend="numpy")
    b, sb = retrieve(buf, error_bound=1e-3, backend="jax", batch_chunks=True)
    assert np.array_equal(a, b)
    assert sa.bytes_read == sb.bytes_read
    a2, _ = retrieve(sa.reader, state=sa, backend="numpy")
    b2, _ = retrieve(sb.reader, state=sb, backend="jax", batch_chunks=True)
    assert np.array_equal(a2, b2)
    assert metrics.linf(x, b2) <= 1e-6


def test_f32_dtype_preserved():
    x = smooth_field((50, 60), 2).astype(np.float32)
    buf = compress(x, 1e-3)
    b = decompress(buf, backend="jax")
    assert b.dtype == np.float32
    assert np.array_equal(decompress(buf, backend="numpy"), b)


def test_bitrate_mode_parity():
    x = smooth_field((64, 64), 4)
    buf = compress(x, 1e-7, CUBIC)
    for bpp in (0.5, 2.0):
        a, sa = retrieve(buf, bitrate=bpp, backend="numpy")
        b, sb = retrieve(buf, bitrate=bpp, backend="jax")
        assert np.array_equal(a, b)
        assert sa.bytes_read == sb.bytes_read


# ----------------------------------------------------- primitive parity

def _dec_parity(q, wants=None):
    q = np.asarray(q, np.int64)
    nb = nbmod.to_negabinary(q)
    blobs, nbits = bp.encode_level(nb)
    if wants is None:
        wants = sorted({0, 1, nbits // 2, max(nbits - 1, 0), nbits})
    for want in wants:
        loaded = [blobs[i] if i < want else None for i in range(nbits)]
        a = bp.decode_level(loaded, nbits, q.size)
        b = jax_backend.decode_level(loaded, nbits, q.size)
        assert np.array_equal(a, b), f"want={want}"


@pytest.mark.parametrize("n", [1, 7, 255, 4096, 4097, 8192 + 3])
def test_decode_level_parity_padding_edges(n):
    rng = np.random.default_rng(n)
    _dec_parity(rng.integers(-(1 << 20), 1 << 20, n))


def test_decode_level_parity_all_zero_middle_plane():
    """b'' (loaded, all-zero encoded plane) must still XOR-propagate."""
    _dec_parity(np.full(500, 5, np.int64))


def test_decode_level_parity_extreme_bins():
    rng = np.random.default_rng(0)
    q = rng.integers(-(1 << 30), 1 << 30, 3000)
    q[0], q[1] = (1 << 30), -(1 << 30)
    _dec_parity(q)


def test_decode_level_empty_and_nbits_zero():
    assert np.array_equal(jax_backend.decode_level([], 0, 0),
                          np.zeros(0, np.uint32))
    assert np.array_equal(jax_backend.decode_level([None] * 5, 5, 100),
                          np.zeros(100, np.uint32))


@given(st.lists(st.integers(-(1 << 30), 1 << 30), min_size=1, max_size=300))
def test_decode_level_parity_property(vals):
    _dec_parity(np.array(vals, np.int64))


def test_reconstruct_parity_direct():
    """jax_backend.reconstruct == interpolation.reconstruct bit-for-bit on
    a full-precision residual set with overrides."""
    rng = np.random.default_rng(5)
    shape = (19, 23)
    L = interpolation.num_levels(shape)
    sizes = interpolation.level_sizes(shape, L)
    anchors_shape = np.zeros(shape)[interpolation.anchor_slices(shape, L)].shape
    anchors = rng.standard_normal(anchors_shape)
    yhat = [rng.standard_normal(n) for n in sizes]
    overrides = []
    for n in sizes:
        k = min(3, n)
        idx = np.sort(rng.choice(n, size=k, replace=False)).astype(np.int64)
        overrides.append((idx, rng.standard_normal(k) * 1e6))
    a = interpolation.reconstruct(shape, CUBIC, anchors, yhat,
                                  overrides=overrides)
    b = jax_backend.reconstruct(shape, CUBIC, anchors, yhat,
                                overrides=overrides)
    assert np.array_equal(a, b)


# ------------------------------------------------------------- registry

def test_registry_resolution():
    assert backends.get("numpy").name == "numpy"
    assert backends.get("jax").name == "jax"
    assert backends.get(None).name in ("numpy", "jax")
    assert backends.get("auto").name == backends.get(None).name
    assert backends.names() == ["numpy", "jax"] or \
        backends.names() == sorted(backends.names())
    with pytest.raises(ValueError):
        backends.get("cuda")
    # the historical alias keeps working and agrees with the registry
    assert jax_backend.resolve("jax") == "jax"
    assert jax_backend.resolve(None) == backends.resolve_name(None)
