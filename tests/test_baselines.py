"""Baseline compressors: error-bound and progressive-behaviour contracts."""
import numpy as np
import pytest

from repro.core import metrics
from repro.core.baselines import PMGARD, SZ3, SZ3M, SZ3R, ZFP, ZFPR


def smooth_field(shape, seed=0, noise=0.01):
    rng = np.random.default_rng(seed)
    grids = np.meshgrid(*[np.linspace(0, 3 * np.pi, s) for s in shape],
                        indexing="ij")
    x = np.ones(shape)
    for i, g in enumerate(grids):
        x = x * np.sin(g * (0.7 + 0.3 * i))
    return x + noise * rng.standard_normal(shape)


X2 = smooth_field((48, 56))
X3 = smooth_field((24, 32, 28))


@pytest.mark.parametrize("comp", [SZ3(), ZFP(), PMGARD()])
@pytest.mark.parametrize("x", [X2, X3], ids=["2d", "3d"])
def test_baseline_roundtrip_bound(comp, x):
    eb = 1e-4 * (x.max() - x.min())
    xh = comp.decompress(comp.compress(x, eb))
    assert metrics.linf(x, xh) <= eb * (1 + 1e-12)


@pytest.mark.parametrize("comp", [SZ3M(), SZ3R(), ZFPR(), PMGARD()])
def test_progressive_baseline_bounds(comp):
    x = X3
    eb = 1e-6 * (x.max() - x.min())
    buf = comp.compress(x, eb)
    for E in (1e-1, 1e-3):
        out, bytes_read, passes = comp.retrieve(buf, error_bound=E)
        assert metrics.linf(x, out) <= E
        assert bytes_read <= len(buf)


def test_residual_multipass_cost():
    """Residual baselines pay one decompression pass per rung (paper's point)."""
    x = X2
    comp = SZ3R()
    buf = comp.compress(x, 1e-7)
    _, _, passes_hi = comp.retrieve(buf, error_bound=1e-1)
    _, _, passes_lo = comp.retrieve(buf, error_bound=1e-6)
    assert passes_lo > passes_hi >= 1


def test_residual_ladder_limited_fidelity():
    """SZ3-R only hits its predefined rungs: requesting between rungs loads
    the next-finer rung (IPComp supports arbitrary eb; baselines do not)."""
    x = X2
    comp = SZ3R()
    eb = 1e-7
    buf = comp.compress(x, eb)
    # rungs at eb*2^k: ...6.55e-3, 1.64e-3, 4.1e-4...; both requests below
    # land in the same inter-rung gap -> same rung is loaded
    out_a, bytes_a, _ = comp.retrieve(buf, error_bound=3.0e-3)
    out_b, bytes_b, _ = comp.retrieve(buf, error_bound=1.7e-3)
    # both requests fall to the same rung -> identical volume
    assert bytes_a == bytes_b


def test_sz3m_not_progressive():
    """SZ3-M re-reads a full archive per fidelity level (no reuse)."""
    x = X2
    comp = SZ3M()
    buf = comp.compress(x, 1e-7)
    _, b1, _ = comp.retrieve(buf, error_bound=1e-2)
    _, b2, _ = comp.retrieve(buf, error_bound=1e-5)
    assert b2 > b1  # finer request reloads a strictly larger archive
