"""Roofline extraction: HLO collective parser + term arithmetic."""
import numpy as np

from repro.launch.roofline import (Roofline, collective_bytes, _shape_bytes,
                                   model_flops, PEAK_FLOPS, HBM_BW, LINK_BW)


HLO_SNIPPET = """
  %all-gather.1 = bf16[16,4096,448]{2,1,0} all-gather(bf16[1,4096,448]{2,1,0} %param.3), replica_groups={{0,1}}, dimensions={0}
  %all-reduce.7 = f32[1024]{0} all-reduce(f32[1024]{0} %add.1), to_apply=%sum
  %reduce-scatter.2 = (f32[8,128]{1,0}, f32[8,128]{1,0}) reduce-scatter(f32[16,128]{1,0} %p0, f32[16,128]{1,0} %p1), dimensions={0}
  %collective-permute.1 = u32[64]{0} collective-permute(u32[64]{0} %x), source_target_pairs={{0,1}}
  %dot.5 = f32[128,128]{1,0} dot(f32[128,64]{1,0} %a, f32[64,128]{1,0} %b)
"""


def test_collective_parser_kinds_and_bytes():
    got = collective_bytes(HLO_SNIPPET)
    assert got["all-gather"] == 16 * 4096 * 448 * 2
    assert got["all-reduce"] == 1024 * 4
    assert got["reduce-scatter"] == 2 * 8 * 128 * 4
    assert got["collective-permute"] == 64 * 4
    assert "dot" not in got  # non-collectives ignored


def test_shape_bytes_tuple():
    assert _shape_bytes("(f32[2,3]{1,0}, bf16[4]{0})") == 2 * 3 * 4 + 4 * 2


def test_roofline_terms_and_bottleneck():
    r = Roofline(arch="a", shape="train_4k", mesh="16x16", chips=256,
                 hlo_flops=256 * PEAK_FLOPS,      # exactly 1s of compute
                 hlo_bytes=256 * HBM_BW * 0.5,    # 0.5s of memory
                 coll_bytes=256 * LINK_BW * 2.0,  # 2s of collectives
                 coll_breakdown={}, model_flops=256 * PEAK_FLOPS * 0.5)
    assert abs(r.t_compute - 1.0) < 1e-9
    assert abs(r.t_memory - 0.5) < 1e-9
    assert abs(r.t_collective - 2.0) < 1e-9
    assert r.bottleneck == "collective"
    assert abs(r.roofline_fraction - 0.25) < 1e-9  # 0.5s ideal / 2s worst
    assert abs(r.useful_flops_ratio - 0.5) < 1e-9


def test_model_flops_kinds():
    from repro.configs import get_config, get_shape
    cfg = get_config("yi-6b")
    t = model_flops(cfg, get_shape("train_4k"))
    p = model_flops(cfg, get_shape("prefill_32k"))
    d = model_flops(cfg, get_shape("decode_32k"))
    n = cfg.param_count()
    assert abs(t - 6 * n * 4096 * 256) / t < 1e-6
    assert abs(p - 2 * n * 32768 * 32) / p < 1e-6
    assert abs(d - 2 * n * 128) / d < 1e-6
    # MoE uses active params
    moe = get_config("kimi-k2-1t-a32b")
    tm = model_flops(moe, get_shape("train_4k"))
    assert tm < 6 * moe.param_count() * 4096 * 256 * 0.2
