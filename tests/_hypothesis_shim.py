"""Tiny deterministic stand-in for ``hypothesis`` (used when it is absent).

The tier-1 container does not ship hypothesis and nothing may be
pip-installed into it, yet the codec invariants in ``test_core_codec.py``
(and the backend-parity suite) are property tests.  This shim implements
just the strategy surface those files use — ``integers``, ``floats``,
``lists``, ``sampled_from`` — and a ``@given`` that replays a fixed number
of seeded pseudo-random examples, biased toward the endpoints (where the
codec's edge cases live).

It is NOT hypothesis: no shrinking, no example database, no coverage
feedback.  When real hypothesis is installed (e.g. in CI, see
``requirements-dev.txt``), the ``try/except ImportError`` in the test files
picks it instead and this module is never imported.
"""
from __future__ import annotations

import functools
import zlib
from typing import Sequence

import numpy as np

DEFAULT_EXAMPLES = 20


class _Strategy:
    def example(self, rng: np.random.Generator):  # pragma: no cover
        raise NotImplementedError


class _Integers(_Strategy):
    def __init__(self, lo: int, hi: int):
        self.lo, self.hi = int(lo), int(hi)

    def example(self, rng):
        if rng.random() < 0.2:  # endpoint bias
            return int(rng.choice([self.lo, self.hi, 0 if
                                   self.lo <= 0 <= self.hi else self.lo]))
        return int(rng.integers(self.lo, self.hi, endpoint=True))


class _Floats(_Strategy):
    def __init__(self, lo: float, hi: float):
        self.lo, self.hi = float(lo), float(hi)

    def example(self, rng):
        if rng.random() < 0.15:
            return float(rng.choice([self.lo, self.hi]))
        if self.lo > 0:  # log-uniform across positive ranges (eb-style args)
            return float(np.exp(rng.uniform(np.log(self.lo), np.log(self.hi))))
        return float(rng.uniform(self.lo, self.hi))


class _Lists(_Strategy):
    def __init__(self, elem: _Strategy, min_size: int = 0, max_size: int = 32):
        self.elem, self.min_size, self.max_size = elem, min_size, max_size

    def example(self, rng):
        n = int(rng.integers(self.min_size, self.max_size, endpoint=True))
        return [self.elem.example(rng) for _ in range(n)]


class _SampledFrom(_Strategy):
    def __init__(self, options: Sequence):
        self.options = list(options)

    def example(self, rng):
        return self.options[int(rng.integers(len(self.options)))]


class strategies:  # namespace mirroring ``hypothesis.strategies``
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Integers(min_value, max_value)

    @staticmethod
    def floats(min_value: float, max_value: float) -> _Strategy:
        return _Floats(min_value, max_value)

    @staticmethod
    def lists(elements: _Strategy, min_size: int = 0,
              max_size: int = 32) -> _Strategy:
        return _Lists(elements, min_size, max_size)

    @staticmethod
    def sampled_from(options: Sequence) -> _Strategy:
        return _SampledFrom(options)


def given(*strats: _Strategy):
    """Run the test once per generated example (seeded by the test name)."""
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_max_examples", DEFAULT_EXAMPLES)
            rng = np.random.default_rng(zlib.crc32(fn.__name__.encode()))
            for _ in range(n):
                fn(*args, *(s.example(rng) for s in strats), **kwargs)
        # hide the strategy parameters from pytest's fixture resolution
        # (inspect.signature follows __wrapped__ set by functools.wraps)
        del wrapper.__wrapped__
        return wrapper
    return deco


def settings(max_examples: int = DEFAULT_EXAMPLES, **_ignored):
    """Applied above @given: stamps the example count onto its wrapper."""
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco
