"""Pallas kernels vs pure-jnp oracles (interpret mode, shape/dtype sweeps)."""
import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest


def _seed(*key) -> int:
    """Deterministic per-case RNG seed (``hash()`` of strings is randomized
    per process, which made the sweep data — and one-in-many-runs edge-case
    draws — unreproducible)."""
    return zlib.crc32(repr(key).encode())

from repro.kernels.interp_quant import interp_quant, interp_quant_ref
from repro.kernels.interp_recon import interp_recon, interp_recon_ref
from repro.kernels.bitplane_pack import (bitplane_pack, bitplane_pack_ref,
                                         bitplane_unpack,
                                         bitplane_unpack_ref,
                                         unpack_planes_ref)
from repro.core import negabinary as nbmod
from repro.core import bitplane as bpmod
from repro.core import interpolation


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
@pytest.mark.parametrize("shape,s", [
    ((8, 128), 1), ((8, 128), 4), ((16, 256), 1), ((16, 256), 8),
    ((3, 96), 1),          # unaligned rows -> wrapper pads
    ((8, 130), 1),         # odd width, boundary fallback at right edge
    ((8, 129), 2),         # odd width, stride 2
    ((40, 512), 16),
])
@pytest.mark.parametrize("interp", ["linear", "cubic"])
def test_interp_quant_matches_ref(shape, s, interp, dtype):
    if dtype == jnp.float64 and not jax.config.read("jax_enable_x64"):
        pytest.skip("x64 disabled")
    rng = np.random.default_rng(_seed(shape, s, interp))
    R, C = shape
    if len(range(s, C, 2 * s)) == 0:
        pytest.skip("no targets")
    x = jnp.asarray(rng.standard_normal(shape), dtype)
    # xhat: known points only (even multiples of s carry values)
    xh = jnp.asarray(rng.standard_normal(shape), dtype)
    eb = 1e-3
    q, pred = interp_quant(x, xh, s=s, eb=eb, interp=interp)
    q_ref, pred_ref = interp_quant_ref(x, xh, s, eb, interp)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q_ref))
    np.testing.assert_allclose(np.asarray(pred), np.asarray(pred_ref),
                               rtol=1e-6, atol=1e-6)


def test_interp_quant_error_bound():
    """Reconstruction pred + 2eb*q at targets obeys |x - recon| <= eb."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((16, 256)), jnp.float32)
    xh = jnp.asarray(rng.standard_normal((16, 256)), jnp.float32)
    eb = 1e-2
    q, pred = interp_quant(x, xh, s=2, eb=eb)
    recon = np.asarray(pred, np.float64) + \
        np.asarray(q, np.float64) * (2.0 * eb)
    tgt = np.asarray(x)[:, 2::4]
    assert np.abs(tgt - recon).max() <= eb * (1 + 1e-5)


@pytest.mark.parametrize("shape", [(8, 32), (8, 128), (16, 256), (5, 96),
                                   (8, 131)])
def test_bitplane_pack_matches_ref(shape):
    rng = np.random.default_rng(shape[1])
    q = rng.integers(-(1 << 20), 1 << 20, size=shape).astype(np.int32)
    packed, n = bitplane_pack(q)
    # oracle on the padded array the wrapper actually packed
    R, C = shape
    pr, pc = (-R) % 8, (-C) % 32
    qp = np.pad(q, ((0, pr), (0, pc)))
    ref = bitplane_pack_ref(jnp.asarray(qp))
    np.testing.assert_array_equal(np.asarray(packed), np.asarray(ref))


@pytest.mark.parametrize("keep", [0, 1, 5, 17, 32])
def test_bitplane_pack_prefix_decodes_to_truncation(keep):
    """Kernel planes decode (via oracle) to negabinary truncation — the same
    invariant the CPU container relies on (§4.4)."""
    rng = np.random.default_rng(keep)
    q = rng.integers(-(1 << 24), 1 << 24, size=(8, 64)).astype(np.int32)
    packed, _ = bitplane_pack(q)
    got_nb = np.asarray(unpack_planes_ref(jnp.asarray(packed), keep))
    want = nbmod.truncate(nbmod.to_negabinary(q.astype(np.int64).ravel()),
                          32 - keep).reshape(8, 64)
    np.testing.assert_array_equal(got_nb, want.astype(np.uint32))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
@pytest.mark.parametrize("shape,s", [
    ((8, 128), 1), ((8, 128), 4), ((16, 256), 8),
    ((3, 96), 1),          # unaligned rows -> wrapper pads
    ((8, 130), 1),         # odd width, boundary fallback at right edge
    ((8, 129), 2),         # odd width, stride 2
])
@pytest.mark.parametrize("interp", ["linear", "cubic"])
def test_interp_recon_matches_ref(shape, s, interp, dtype):
    if dtype == jnp.float64 and not jax.config.read("jax_enable_x64"):
        pytest.skip("x64 disabled")
    rng = np.random.default_rng(_seed("recon", shape, s, interp))
    R, C = shape
    T = len(range(s, C, 2 * s))
    if T == 0:
        pytest.skip("no targets")
    xh = jnp.asarray(rng.standard_normal(shape), dtype)
    res = jnp.asarray(rng.standard_normal((R, T)), dtype)
    out = interp_recon(xh, res, s=s, interp=interp)
    ref = interp_recon_ref(xh, res, s, interp)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_interp_recon_bit_identical_to_numpy_sweep():
    """Decode kernel == interpolation.predict_block + res, bitwise (f64) —
    the invariant that makes jax retrieval parity possible at all."""
    with jax.experimental.enable_x64():
        rng = np.random.default_rng(11)
        for (R, C), s, interp in [((8, 128), 1, "cubic"),
                                  ((5, 257), 4, "cubic"),
                                  ((3, 96), 2, "linear")]:
            xh = rng.standard_normal((R, C)) * 50
            idx = np.arange(s, C, 2 * s)
            res = rng.standard_normal((R, idx.size))
            out = np.asarray(interp_recon(xh, res, s=s, interp=interp))
            pred = interpolation.predict_block(xh, 1, idx, s, C, interp)
            np.testing.assert_array_equal(out, pred + res)


def test_interp_recon_inverts_interp_quant():
    """recon(xhat, dequantized q) == the encode sweep's lossy writeback."""
    with jax.experimental.enable_x64():
        rng = np.random.default_rng(3)
        x = rng.standard_normal((8, 256))
        xh = rng.standard_normal((8, 256))
        eb = 1e-3
        q, pred = interp_quant(x, xh, s=2, eb=eb)
        res = np.asarray(q, np.float64) * (2.0 * eb)
        recon = np.asarray(interp_recon(xh, res, s=2))
        want = np.asarray(pred, np.float64) + res
        np.testing.assert_array_equal(recon, want)
        tgt = x[:, 2::4]
        assert np.abs(tgt - recon).max() <= eb * (1 + 1e-12)


@pytest.mark.parametrize("keep", [0, 1, 5, 17, 32])
def test_bitplane_unpack_kernel_matches_truncation(keep):
    """Closed-form XOR-inverse kernel == sequential oracle == negabinary
    truncation, over a pack -> unpack round trip."""
    rng = np.random.default_rng(keep + 100)
    n = 5000
    q = rng.integers(-(1 << 30), 1 << 30, n).astype(np.int32)
    q[0], q[1] = (1 << 30), -(1 << 30)
    packed, _ = bitplane_pack(q)
    packed = np.asarray(packed)
    words = packed.reshape(32, -1).copy()
    low = 32 - keep
    if low > 0:
        words[:low] = 0           # absent planes arrive as all-zero streams
    got = np.asarray(bitplane_unpack(words, n=n, low_zero=low))
    want_nb = nbmod.truncate(nbmod.to_negabinary(q.astype(np.int64)), low)
    want = nbmod.from_negabinary(want_nb)
    np.testing.assert_array_equal(got.astype(np.int64), want)
    ref = np.asarray(bitplane_unpack_ref(jnp.asarray(packed),
                                         keep)).reshape(-1)[:n]
    np.testing.assert_array_equal(ref.astype(np.int64), want)


@pytest.mark.parametrize("n", [1, 31, 32, 33, 4096, 4097])
def test_bitplane_unpack_padding_edges(n):
    """n not a multiple of the word/row geometry: full round trip exact."""
    rng = np.random.default_rng(n)
    q = rng.integers(-(1 << 24), 1 << 24, n).astype(np.int32)
    packed, _ = bitplane_pack(q)
    words = np.asarray(packed).reshape(32, -1)
    got = np.asarray(bitplane_unpack(words, n=n, low_zero=0))
    np.testing.assert_array_equal(got, q)


def test_bitplane_pack_agrees_with_cpu_container_bits():
    """Plane k bit content matches the CPU pipeline's XOR-encoded plane k."""
    rng = np.random.default_rng(3)
    q = rng.integers(-(1 << 15), 1 << 15, size=(8, 32)).astype(np.int32)
    packed, _ = bitplane_pack(q)
    nb = nbmod.to_negabinary(q.astype(np.int64).ravel())
    planes = bpmod.split_planes(nb, 32)
    enc = bpmod.xor_encode(planes)
    for k in (0, 3, 12, 31):
        word = np.asarray(packed[k]).reshape(8, 1)
        bits = ((word >> np.arange(31, -1, -1, dtype=np.uint32)) & 1).ravel()
        np.testing.assert_array_equal(bits.astype(np.uint8), enc[k])
