"""Flash-attention Pallas kernel vs oracle (interpret mode, shape sweep)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.attention import attention_ref, flash_attention_tpu


@pytest.mark.parametrize("B,S,H,KV,D", [
    (1, 256, 4, 4, 64),      # MHA, one q tile
    (2, 512, 8, 2, 64),      # GQA 4:1, two q tiles
    (1, 512, 4, 1, 128),     # MQA, D=128
    (2, 300, 6, 3, 32),      # ragged Sq (padding path)
])
def test_flash_kernel_matches_ref_causal(B, S, H, KV, D):
    rng = np.random.default_rng(S + H)
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, KV, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KV, D)), jnp.float32)
    got = flash_attention_tpu(q, k, v, causal=True)
    want = attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                         v.transpose(0, 2, 1, 3),
                         causal=True).transpose(0, 2, 1, 3)
    # padded ragged case: padded q rows attend only to real keys <= row,
    # compare the valid region
    np.testing.assert_allclose(np.asarray(got), np.asarray(want)[:, :S],
                               atol=2e-5, rtol=2e-5)


def test_flash_kernel_bf16():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((1, 256, 4, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((1, 256, 2, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((1, 256, 2, 64)), jnp.bfloat16)
    got = flash_attention_tpu(q, k, v, causal=True)
    want = attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                         v.transpose(0, 2, 1, 3),
                         causal=True).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=3e-2, rtol=3e-2)


def test_flash_kernel_matches_xla_flash():
    """Pallas kernel == the pure-XLA flash used by the dry-run."""
    from repro.models.layers import flash_attention
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.standard_normal((2, 256, 4, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 256, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 256, 2, 64)), jnp.float32)
    a = flash_attention_tpu(q, k, v, causal=True)
    b = flash_attention(q, k, v, causal=True, q_chunk=64, kv_chunk=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=2e-5, rtol=2e-5)
