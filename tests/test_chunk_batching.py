"""Batched chunk execution: vmapped shape-group scheduling parity.

The acceptance bar of the batched chunk engine: grouping a v2 archive's
equal-shaped chunks and running the codec primitives once per group via
``jax.vmap`` must (a) emit byte-identical archives and bit-identical
reconstructions — refine deltas included — to the per-chunk loop on both
backends, and (b) issue strictly fewer kernel dispatches than chunks x
levels.  The batch axis is an execution detail, never a format change.
"""
import numpy as np
import pytest

from _fields import smooth_field
from repro.core import (CUBIC, ChunkedRetrievalState, compress, decompress,
                        metrics, open_archive, refine, retrieve)
from repro.core.pipeline import backends, shape_groups
from repro.kernels import dispatch


def _chunky_field(shape=(50, 41), seed=0, rough=0.01):
    rng = np.random.default_rng(seed)
    return smooth_field(shape, seed) + rough * rng.standard_normal(shape)


# ------------------------------------------------------- group scheduling

def test_shape_groups_structure():
    # typical chunk grid: equal interior slabs + ragged tail
    assert shape_groups([12, 12, 12, 2]) == [[0, 1, 2], [3]]
    assert shape_groups([7]) == [[0]]                  # single chunk
    assert shape_groups([3, 3, 3, 3]) == [[0, 1, 2, 3]]
    # arbitrary mixtures keep first-occurrence order, ascending indices
    assert shape_groups([5, 2, 5, 2, 9]) == [[0, 2], [1, 3], [4]]


def test_shape_groups_caps_batch_size():
    """A batched stack materializes its whole group in memory, so big
    groups split into max_group-sized runs (chunking must keep bounding
    working memory)."""
    assert shape_groups([3] * 7, max_group=3) == [[0, 1, 2], [3, 4, 5], [6]]
    assert shape_groups([3] * 40) == [list(range(16)), list(range(16, 32)),
                                      list(range(32, 40))]
    assert shape_groups([3] * 40, max_group=None) == [list(range(40))]


def test_decode_level_batch_mixed_prefixes_one_dispatch():
    """low_zero is a RUNTIME kernel operand now: streams with different
    loaded prefixes share ONE batched dispatch and each decodes exactly
    like its scalar call."""
    from repro.core import jax_backend
    q = np.arange(-50, 50, dtype=np.int64)
    blobs, nbits = jax_backend.encode_level(q)
    full = list(blobs)
    shorter = [blobs[i] if i < nbits - 1 else None for i in range(nbits)]
    shortest = [blobs[i] if i < 2 else None for i in range(nbits)]
    prefixes = [full, shorter, shortest]
    with dispatch.measure() as d:
        out = jax_backend.decode_level_batch(prefixes, nbits, q.size)
    assert d["bitplane_unpack"] == 1
    for got, bl in zip(out, prefixes):
        assert np.array_equal(got, jax_backend.decode_level(bl, nbits,
                                                            q.size))


def test_backend_batch_slots():
    """jax ships the batched primitives; numpy deliberately loops."""
    jx, np_ = backends.get("jax"), backends.get("numpy")
    assert jx.batches_encode and jx.batches_decode
    assert not np_.batches_encode and not np_.batches_decode


# --------------------------------------------------------- encode parity

@pytest.mark.parametrize("shape,chunk", [((50, 41), 500), ((3000,), 700),
                                         ((24, 20, 18), 2000)])
def test_batched_compress_byte_identical(shape, chunk):
    """Batched, looped, and numpy archives are the same bytes — including
    the ragged tail chunk every shape here produces."""
    x = _chunky_field(shape)
    b_loop = compress(x, 1e-5, CUBIC, backend="jax", chunk_elems=chunk,
                      batch_chunks=False)
    b_bat = compress(x, 1e-5, CUBIC, backend="jax", chunk_elems=chunk,
                     batch_chunks=True)
    b_np = compress(x, 1e-5, CUBIC, backend="numpy", chunk_elems=chunk)
    assert b_bat == b_loop == b_np


def test_batched_compress_fewer_dispatches_than_chunks_x_levels():
    """The point of batching: per-level pack launches collapse from one
    per (chunk, level) to one per (shape-group, level)."""
    x = _chunky_field((48, 41))
    with dispatch.measure() as loop:
        compress(x, 1e-5, backend="jax", chunk_elems=500, batch_chunks=False)
    with dispatch.measure() as bat:
        buf = compress(x, 1e-5, backend="jax", chunk_elems=500,
                       batch_chunks=True)
    r = open_archive(buf)
    n_chunks = len(r.meta.chunks)
    n_levels = r.chunk_reader(0).meta.L
    assert n_chunks >= 3
    # looped: one pack dispatch per non-empty (chunk, level)
    assert loop["bitplane_pack"] > bat["bitplane_pack"]
    assert bat["bitplane_pack"] < n_chunks * n_levels
    # the sweep dispatches shrink too, and so does the overall count
    assert bat["interp_quant"] < loop["interp_quant"]
    assert sum(bat.values()) < sum(loop.values())


def test_numpy_backend_batch_flag_is_noop():
    """numpy has no batched slots: batch_chunks=True falls back to the
    loop instead of erroring, and bytes are unchanged."""
    x = _chunky_field((30, 20))
    a = compress(x, 1e-4, backend="numpy", chunk_elems=200,
                 batch_chunks=True)
    b = compress(x, 1e-4, backend="numpy", chunk_elems=200,
                 batch_chunks=False)
    assert a == b


def test_single_chunk_archive_batched_path():
    """A one-chunk v2 archive is a singleton group: the scheduler must
    fall through to the scalar path and still round-trip."""
    x = _chunky_field((16, 10))
    buf = compress(x, 1e-5, backend="jax", chunk_elems=10 ** 6,
                   batch_chunks=True)
    r = open_archive(buf)
    assert len(r.meta.chunks) == 1
    out, st = retrieve(r, error_bound=1e-3, backend="jax",
                       batch_chunks=True)
    assert metrics.linf(x, out) <= 1e-3
    assert np.array_equal(out, retrieve(buf, error_bound=1e-3,
                                        backend="numpy")[0])


# --------------------------------------------------------- decode parity

@pytest.mark.parametrize("mode", [dict(error_bound=1e-3),
                                  dict(max_bytes=3000), dict()])
def test_batched_retrieve_bit_identical(mode):
    """Every plan mode: batched jax == looped jax == numpy, bit for bit,
    with identical per-chunk byte accounting."""
    x = _chunky_field((50, 41))
    buf = compress(x, 1e-5, chunk_elems=500)
    a, sa = retrieve(open_archive(buf), backend="jax", batch_chunks=False,
                     **mode)
    b, sb = retrieve(open_archive(buf), backend="jax", batch_chunks=True,
                     **mode)
    c, sc = retrieve(open_archive(buf), backend="numpy", **mode)
    assert np.array_equal(a, b) and np.array_equal(a, c)
    assert sa.bytes_read == sb.bytes_read == sc.bytes_read
    assert sa.err_bound == sb.err_bound == sc.err_bound
    for ca, cb in zip(sa.chunk_states, sb.chunk_states):
        assert ca.planes_loaded == cb.planes_loaded
        assert ca.bytes_read == cb.bytes_read


def test_batched_retrieve_fewer_dispatches():
    x = _chunky_field((50, 41))
    buf = compress(x, 1e-5, chunk_elems=500)
    with dispatch.measure() as loop:
        retrieve(open_archive(buf), error_bound=1e-3, backend="jax",
                 batch_chunks=False)
    with dispatch.measure() as bat:
        retrieve(open_archive(buf), error_bound=1e-3, backend="jax",
                 batch_chunks=True)
    r = open_archive(buf)
    n_chunks = len(r.meta.chunks)
    n_levels = r.chunk_reader(0).meta.L
    assert bat["interp_recon"] < loop["interp_recon"]
    # the jax decode path runs the fused megakernel: plane unpack +
    # dequantize + delta are one launch per (group, level)
    assert bat.get("decode_fused", 0) <= loop["decode_fused"]
    assert bat.get("decode_fused", 0) < n_chunks * n_levels
    assert sum(bat.values()) < sum(loop.values())


def test_batched_refine_deltas_bit_identical_and_no_rereads():
    """Algorithm 2 on the batched engine: every rung of a progressive
    ladder matches the looped ladder bit-for-bit, refine still loads only
    missing planes (cumulative bytes equal the loop's at every step), and
    the final state reaches full precision."""
    x = _chunky_field((80, 44), 2)
    buf = compress(x, 1e-6, CUBIC, chunk_elems=900)
    ladders = {}
    for bc in (False, True):
        r = open_archive(buf)
        st, rungs = None, []
        for E in (1e-1, 1e-3, None):
            kw = {} if E is None else dict(error_bound=E)
            out, st = retrieve(r, state=st, backend="jax", batch_chunks=bc,
                               **kw)
            rungs.append((out.copy(), st.bytes_read))
        ladders[bc] = (rungs, st)
    for (o1, b1), (o2, b2) in zip(ladders[False][0], ladders[True][0]):
        assert np.array_equal(o1, o2)
        assert b1 == b2
    # repeating the final bound adds no bytes (nothing re-read)
    st = ladders[True][1]
    prev = st.bytes_read
    out, st = refine(st, backend="jax", batch_chunks=True)
    assert st.bytes_read == prev
    assert metrics.linf(x, out) <= 1e-6


def test_batched_refine_mixed_prefix_groups():
    """Byte-budget plans give each chunk a different plane prefix, so the
    (nbits, prefix) decode grouping sees mixed keys — results must still
    match the loop exactly."""
    rng = np.random.default_rng(3)
    x = smooth_field((60, 33), 1)
    x[:20] += 0.5 * rng.standard_normal((20, 33))  # chunk 0 much rougher
    buf = compress(x, 1e-6, chunk_elems=700)
    for budget in (4000, 9000):
        a, sa = retrieve(open_archive(buf), max_bytes=budget, backend="jax",
                         batch_chunks=False)
        b, sb = retrieve(open_archive(buf), max_bytes=budget, backend="jax",
                         batch_chunks=True)
        assert np.array_equal(a, b)
        assert sa.bytes_read == sb.bytes_read


def test_batched_backend_switch_mid_refinement():
    """State stays backend- and batching-agnostic: numpy-started ladders
    refined on the batched jax engine equal the pure loop."""
    x = _chunky_field((40, 30), 7)
    buf = compress(x, 1e-6, chunk_elems=400)
    r1 = open_archive(buf)
    out1, st1 = retrieve(r1, error_bound=1e-2, backend="numpy")
    out1, st1 = retrieve(r1, error_bound=1e-5, state=st1, backend="jax",
                         batch_chunks=True)
    r2 = open_archive(buf)
    out2, st2 = retrieve(r2, error_bound=1e-2, backend="jax",
                         batch_chunks=True)
    out2, st2 = retrieve(r2, error_bound=1e-5, state=st2, backend="numpy")
    assert np.array_equal(out1, out2)
    assert st1.bytes_read == st2.bytes_read


def test_batched_with_escapes_bit_identical():
    """Escaped outliers land in specific chunks: the per-chunk override
    writeback inside the batched reconstruct must hit the same points."""
    x = smooth_field((40, 40), 1)
    x[13, 17] = 1e15
    x[35, 2] = -1e15
    with np.errstate(invalid="ignore"):
        buf = compress(x, 1e-7, CUBIC, chunk_elems=400)
    a, _ = retrieve(open_archive(buf), error_bound=1e-2, backend="jax",
                    batch_chunks=False)
    b, _ = retrieve(open_archive(buf), error_bound=1e-2, backend="jax",
                    batch_chunks=True)
    assert np.array_equal(a, b)
    assert metrics.linf(x, decompress(buf, backend="jax")) <= 1e-7


def test_batched_chunked_state_type_and_assembly():
    """The chunked state keeps its per-chunk structure under batching and
    the assembled array equals the per-chunk reconstructions."""
    x = _chunky_field((50, 41)).astype(np.float32)
    buf = compress(x, 1e-3, chunk_elems=500)
    out, st = retrieve(open_archive(buf), error_bound=1e-2, backend="jax",
                       batch_chunks=True)
    assert isinstance(st, ChunkedRetrievalState)
    assert out.dtype == np.float32
    for cm, cs in zip(st.reader.meta.chunks, st.chunk_states):
        assert np.array_equal(out[cm.start:cm.stop],
                              cs.xhat.astype(np.float32))
        assert metrics.linf(x[cm.start:cm.stop], cs.xhat) <= 1e-2
