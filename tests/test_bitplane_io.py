"""Lossless-backend knobs: the IPCOMP_ZLIB_LEVEL env and the Raw fast path.

Satellite contract: the encode-side zlib level is configurable per process
(default 6, validated 0..9), archives stay decodable at every setting, and
``bitplane.inflate`` short-circuits already-raw payloads without a zlib
round-trip.
"""
import zlib

import numpy as np
import pytest

from _fields import smooth_field
from repro.core import bitplane, compress, decompress, metrics


def test_zlib_level_default_and_env(monkeypatch):
    monkeypatch.delenv(bitplane.ZLEVEL_ENV, raising=False)
    assert bitplane.zlib_level() == bitplane.ZLEVEL == 6
    monkeypatch.setenv(bitplane.ZLEVEL_ENV, "9")
    assert bitplane.zlib_level() == 9
    monkeypatch.setenv(bitplane.ZLEVEL_ENV, "0")
    assert bitplane.zlib_level() == 0


@pytest.mark.parametrize("bad", ["-1", "10", "fast"])
def test_zlib_level_rejects_bad_values(monkeypatch, bad):
    monkeypatch.setenv(bitplane.ZLEVEL_ENV, bad)
    with pytest.raises(ValueError):
        bitplane.zlib_level()


def test_zlib_level_changes_bytes_not_bits(monkeypatch):
    """Levels 1 and 9 produce different archive bytes but identical
    reconstructions — the knob is a size/speed trade, never a fidelity one."""
    x = smooth_field((40, 37), 7)
    outs, sizes = [], []
    for lvl in ("1", "9"):
        monkeypatch.setenv(bitplane.ZLEVEL_ENV, lvl)
        buf = compress(x, 1e-6)
        sizes.append(len(buf))
        outs.append(decompress(buf))
    monkeypatch.delenv(bitplane.ZLEVEL_ENV)
    assert sizes[0] != sizes[1]
    assert np.array_equal(outs[0], outs[1])
    assert metrics.linf(x, outs[0]) <= 1e-6


def test_inflate_raw_fast_path():
    payload = bytes(np.arange(64, dtype=np.uint8))
    # Raw passes through untouched — payload is NOT a valid zlib stream
    assert bitplane.inflate(bitplane.Raw(payload)) == payload
    # falsy conventions
    assert bitplane.inflate(b"") == b""
    assert bitplane.inflate(None) == b""
    # plain bytes are a stored zlib blob
    assert bitplane.inflate(zlib.compress(payload, 1)) == payload
