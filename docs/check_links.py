"""Relative-link checker for the docs suite (CI's docs lane).

Scans markdown files for inline links/images ``[text](target)`` and fails
if a *relative* target does not exist on disk (anchors are stripped;
absolute URLs and mailto are ignored).  Anchor-only links (``#section``)
are accepted as long as the file itself exists.

Usage:
  python docs/check_links.py [file-or-dir ...]      # default: docs/ README.md
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

#: inline markdown link/image: [text](target) — target up to the first ')'
_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)\)")
_SKIP = ("http://", "https://", "mailto:")


def iter_md(paths):
    for p in paths:
        p = Path(p)
        if p.is_dir():
            yield from sorted(p.rglob("*.md"))
        elif p.suffix == ".md":
            yield p


def check_file(md: Path):
    """Yield (line_no, target) for every dead relative link in ``md``."""
    for ln, line in enumerate(md.read_text().splitlines(), 1):
        for target in _LINK.findall(line):
            if target.startswith(_SKIP):
                continue
            path = target.split("#", 1)[0]
            if not path:          # pure anchor into this file
                continue
            if not (md.parent / path).exists():
                yield ln, target


def main(argv) -> int:
    roots = argv or ["docs", "README.md"]
    dead, checked = [], 0
    for md in iter_md(roots):
        checked += 1
        dead += [(md, ln, t) for ln, t in check_file(md)]
    for md, ln, t in dead:
        print(f"DEAD LINK {md}:{ln}: {t}")
    print(f"checked {checked} markdown file(s), "
          f"{len(dead)} dead relative link(s)")
    if not checked:
        print("no markdown files found — wrong working directory?")
        return 2
    return 1 if dead else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
